"""Multi-graph registry: cached device layouts + engines, LRU-evicted.

Serving heterogeneous traffic means holding several preprocessed graphs
at once — each with a device-resident :class:`~repro.core.graph.DeviceGraph`,
one relaxation-backend layout (``BlockedGraph`` bucketing etc.), and the
host-side per-graph serving state (hoisted degree array, eccentricity
hints for batch formation).  Those are exactly the expensive,
re-buildable artifacts, so the registry separates

* the **spec** — how to (re)build a graph, registered once per ``gid``
  and kept forever (a ``HostGraph`` or a zero-arg factory returning one);
* the **engine cache** — at most ``capacity`` built engines, keyed by
  ``(gid, backend, placement)``, recycled LRU.

Placement is the multi-device serving plane's device-affinity axis: the
same graph can be built once per device (the router replicates hot
graphs), with each engine's buffers ``jax.device_put`` on its device so
the jitted query batch runs there without transfers.

**Engine tiers.**  Graphs small enough to fit one device are served by
the single-device vmapped engine (:class:`GraphEngine`).  Graphs above
the registry's vertex/edge shard thresholds are built as
:class:`ShardedGraphEngine` s over :mod:`repro.core.distributed` (v2
sharded-dist ``shard_map``) spanning the whole mesh — both tiers expose
the same ``run_batch`` interface, so the scheduler/planner stack serves
either transparently.

**Concurrency.**  Lookups of built engines take only a short lock.  A
cold build publishes a per-key future and builds *outside* the lock:
concurrent lookups of the same key wait on that future (no duplicate
builds), while lookups of other keys — in particular already-built
engines — proceed immediately instead of serializing behind someone
else's build.

A cache miss on a registered gid transparently rebuilds the engine from
its spec (and re-pays layout preprocessing + jit, which is why the
serving benchmark reports registry hit rates).  :meth:`GraphRegistry.warmup`
pre-pays builds and per-(graph, kind, batch-width) jit compiles before
traffic arrives.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import os
import threading
import time
import weakref
from concurrent.futures import Future
from typing import Callable, Dict, Optional, Tuple, Union

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..core import landmarks as landmarks_mod
from ..core import relax
from ..core.config import EngineConfig, resolve_devices
from ..core.distributed import (blocked_specs, graph_specs, shard_blocked,
                                shard_graph, sssp_distributed_batch,
                                ShardedGraph)
from ..core.graph import DeviceGraph, HostGraph
from ..core.landmarks import LandmarkSet, build_landmarks, hop_bfs
from ..core.sssp import GOALS, repair_relax, sssp_batch
from ..delta import (patch_blocked_with, patch_host, patch_sharded_with,
                     repair_state)
from ..obs import profiling
from ..obs.metrics import MetricsRegistry

__all__ = ["GraphEngine", "ShardedGraphEngine", "GraphRegistry",
           "estimate_eccentricity"]


def _shard_backend_name(backend) -> str:
    """Resolve a relax-backend name/alias to the sharded tier's backend
    (one shared mapping — see :mod:`repro.core.config`)."""
    from ..core.config import _canonical_shard_backend
    return _canonical_shard_backend(backend)


class _StrongRef:
    """weakref.WeakMethod-shaped holder for callables that aren't bound
    methods (plain functions, lambdas)."""

    def __init__(self, cb):
        self._cb = cb

    def __call__(self):
        return self._cb


def estimate_eccentricity(hg, n_landmarks: int = 4,
                          landmarks=None) -> np.ndarray:
    """Per-vertex eccentricity estimate, in hops (host-side, O(k(N + M))).

    One hop-BFS from a landmark ``L_i`` gives hop distances ``h_i(v)``;
    with ``H_i = ecc(L_i)`` (in hops, observed), the triangle inequality
    bounds ``ecc(v) <= H_i + h_i(v)``, and a vertex far from *any*
    landmark is genuinely eccentric — so the estimate is the **max over
    the ``n_landmarks`` highest-degree landmarks** of each per-landmark
    estimate.  A single landmark under-ranks vertices that happen to sit
    near it but far from the rest of the graph; additional vantage
    points recover them.  The absolute value is still crude, but the
    *ordering* is what batch formation needs: sources estimated far run
    more stepping rounds, so grouping nearby estimates keeps a vmapped
    batch from paying one outlier's rounds.  Vertices disconnected from
    a landmark take ``2 * H_i + 1`` for it (worst bucket).

    ``landmarks`` overrides the vantage points with explicit vertex ids
    — an engine that already carries an ALT
    :class:`~repro.core.landmarks.LandmarkSet` reuses its choices, so
    the hint BFS and the ALT preprocessing agree on one landmark set.
    """
    n = hg.n
    if n == 0:
        return np.zeros(0, np.float32)
    row_ptr = np.asarray(hg.row_ptr, np.int64)
    dst = np.asarray(hg.dst, np.int64)
    if landmarks is None:
        if n_landmarks < 1:
            raise ValueError("n_landmarks must be >= 1")
        deg = np.asarray(hg.deg)
        # k distinct max-degree landmarks, ties broken by id (stable)
        landmarks = np.argsort(-deg, kind="stable")[:min(n_landmarks, n)]
    else:
        landmarks = np.asarray(landmarks, np.int64)
        if landmarks.size < 1:
            raise ValueError("landmarks must be non-empty")
    # max over the landmarks that actually *reach* a vertex: on a
    # disconnected graph a foreign component's landmark would otherwise
    # contribute a flat disconnection constant that swamps the local
    # ordering.  Vertices unreached by every landmark share the worst
    # bucket (they have no ordering information at all).
    ecc = np.full(n, -1, np.int64)
    worst = 1
    for lm in landmarks:
        hop = hop_bfs(row_ptr, dst, n, int(lm))
        h_max = int(hop.max())
        ecc = np.where(hop >= 0, np.maximum(ecc, h_max + hop), ecc)
        worst = max(worst, 2 * h_max + 1)
    return np.where(ecc >= 0, ecc, worst).astype(np.float32)


GraphSpec = Union[HostGraph, DeviceGraph, Callable[[], HostGraph]]


class _EngineBase:
    """Shared serving state: eccentricity hints + measured-rounds feedback.

    ``batch_hint`` is what batch formation reads.  It starts as the
    landmark-BFS eccentricity estimate and is EMA-blended with *measured*
    per-source round counts (:meth:`record_rounds`, fed back by the
    scheduler after every batch): vertices that have actually been served
    converge to their true stepping cost, unvisited ones keep the BFS
    prior.  The two are on different scales (hops vs rounds), which is
    fine — grouping only needs a consistent *ordering*, and rounds
    correlate monotonically with hop eccentricity.
    """

    def __init__(self):
        self._ecc_hint: Optional[np.ndarray] = None
        self._batch_hint: Optional[np.ndarray] = None
        self._hint_lock = threading.Lock()
        self.generation = 0     # registry spec generation (stamped on build)
        self.landmarks: Optional[LandmarkSet] = None   # ALT artifact

    @property
    def ecc_hint(self) -> np.ndarray:
        """Lazy landmark-BFS eccentricity estimates (only ecc-aware batch
        formation reads these; FIFO consumers never pay the BFS).  An
        engine carrying an ALT :class:`LandmarkSet` reuses its landmark
        choices as the BFS vantage points."""
        if self._ecc_hint is None:
            lm = (self.landmarks.landmarks
                  if self.landmarks is not None else None)
            self._ecc_hint = estimate_eccentricity(self.host, landmarks=lm)
        return self._ecc_hint

    @property
    def batch_hint(self) -> np.ndarray:
        """Feedback-blended per-vertex stepping-cost estimate (see class
        docstring); identical to ``ecc_hint`` until rounds are fed back."""
        if self._batch_hint is None:
            with self._hint_lock:
                if self._batch_hint is None:
                    self._batch_hint = self.ecc_hint.astype(np.float32,
                                                            copy=True)
        return self._batch_hint

    def peek_batch_hint(self) -> Optional[np.ndarray]:
        """``batch_hint`` only if it is available without running the
        landmark BFS (None otherwise) — safe to call under a scheduler
        lock.  A computed ``ecc_hint`` is promoted (an O(N) copy, no
        BFS); the scheduler pays the BFS itself outside its lock."""
        if self._batch_hint is None and self._ecc_hint is None:
            return None
        return self.batch_hint

    def record_rounds(self, sources, rounds, gamma: float = 0.25) -> None:
        """EMA-blend measured per-source round counts into ``batch_hint``."""
        sources = np.asarray(sources, np.int64)
        rounds = np.asarray(rounds, np.float32)
        if sources.size == 0:
            return
        hint = self.batch_hint
        with self._hint_lock:
            hint[sources] = (1.0 - gamma) * hint[sources] + gamma * rounds


class GraphEngine(_EngineBase):
    """One built (graph, backend) serving entry — the single-device tier.

    Owns the device graph, the backend layout (built once), the hoisted
    host-side degree array, and the batch-formation hints; ``run_batch``
    executes one fused multi-source goal query batch.  With ``device``
    set, graph + layout buffers are ``jax.device_put`` there, making the
    jitted batch device-affine (it runs on that device, no transfers).
    """

    tier = "single"

    def __init__(self, gid: str, hg, backend: str,
                 alpha: float, beta: float, device=None,
                 max_iters: int = 1_000_000, fused_rounds: int = 0,
                 policy: str = "static", landmarks=None,
                 p2p_mode: str = "unidirectional", **backend_opts):
        super().__init__()
        self.gid = gid
        self.host = hg
        self.device = device
        self.max_iters = max_iters
        self.fused_rounds = fused_rounds
        self.policy = policy
        self.p2p_mode = p2p_mode
        g = hg.to_device() if isinstance(hg, HostGraph) else hg
        if device is not None:
            g = jax.device_put(g, device)
            if landmarks is not None:
                # device-affine engines keep the ALT matrix beside the
                # graph so the jitted p2p batch never transfers it
                landmarks = landmarks.placed(device)
        self.landmarks = landmarks
        self.g: DeviceGraph = g
        self.backend = relax.get_backend(backend)
        layout = self.backend.prepare(self.g, **backend_opts)
        if device is not None:
            layout = jax.device_put(layout, device)
        self.layout = layout
        self.alpha = alpha
        self.beta = beta
        # hoisted once: per-slot metric normalization reads this every batch
        self.deg = np.asarray(hg.deg)
        self.n = int(self.deg.shape[0])

    def run_batch(self, sources, goal: str = "tree", goal_params=None):
        """One fused batch; returns ``(dist, parent, metrics)`` with a
        leading slot axis.  Results are *device* arrays — dispatch is
        async, so a caller can overlap host-side work with the device
        computation (the scheduler's double buffering) and force them
        with ``np.asarray`` only when needed."""
        alt = {}
        if goal == "p2p" and self.landmarks is not None:
            alt["landmarks"] = self.landmarks
            if self.p2p_mode == "bidirectional":
                # bidirectional validates as a config only with ALT on
                alt["p2p_mode"] = self.p2p_mode
                alt["use_alt"] = True
        return sssp_batch(
            self.g, np.asarray(sources, np.int32), backend=self.backend,
            layout=self.layout, alpha=self.alpha, beta=self.beta,
            max_iters=self.max_iters,
            fused_rounds=self.fused_rounds or None,
            policy=None if self.policy == "static" else self.policy,
            goal=goal, goal_params=goal_params, **alt)


class ShardedGraphEngine(_EngineBase):
    """The sharded serving tier: one graph spanning the whole device mesh.

    Built for graphs above the registry's shard thresholds, where a
    single device can't (or shouldn't) hold dist/parent + the edge list.
    The graph is block-partitioned with
    :func:`repro.core.distributed.shard_graph`, each slab placed on its
    device via ``NamedSharding``, and batches run through the v2
    sharded-dist ``shard_map`` engine's batch entry point
    (:func:`repro.core.distributed.sssp_distributed_batch`) with the same
    goal semantics as the single-device tier — so the registry/scheduler
    stack serves both tiers through one ``run_batch`` interface.

    ``backend`` selects the per-shard relaxation
    (:data:`repro.core.distributed.DIST_BACKENDS`): ``"blocked"`` builds
    the sparsity-aware per-shard blocked slabs
    (:func:`repro.core.distributed.shard_blocked`, ``block_v``/``tile_e``
    sized) once at engine build and threads them through every batch;
    results are bitwise-identical across backends.
    """

    tier = "sharded"

    def __init__(self, gid: str, hg, alpha: float, beta: float,
                 devices=None, version: str = "v2", fused_rounds: int = 0,
                 backend: str = "segment_min", capacity: int = 0,
                 max_iters: int = 1_000_000, policy: str = "static",
                 landmarks=None, **blocked_opts):
        super().__init__()
        self.gid = gid
        self.host = hg
        self.deg = np.asarray(hg.deg)
        self.n = int(self.deg.shape[0])
        self.alpha = alpha
        self.beta = beta
        self.version = version
        self.fused_rounds = fused_rounds
        self.policy = policy
        self.capacity = capacity
        self.max_iters = max_iters
        self.backend = _shard_backend_name(backend)
        devs = tuple(devices) if devices else tuple(jax.devices())
        self.devices = devs
        self.mesh = Mesh(np.array(devs), ("graph",))
        sg = shard_graph(hg, len(devs))
        # pre-place each slab on its owner device (the engine's layout)
        self.sg = ShardedGraph(*(
            jax.device_put(x, NamedSharding(self.mesh, s))
            for x, s in zip(sg, graph_specs("graph"))))
        self.blocked = None
        if self.backend == "blocked":
            arrays, bmeta = shard_blocked(hg, len(devs), **blocked_opts)
            arrays = type(arrays)(*(
                jax.device_put(x, NamedSharding(self.mesh, s))
                for x, s in zip(arrays, blocked_specs("graph"))))
            self.blocked = (arrays, bmeta)
        if landmarks is not None:
            # the ALT matrix is replicated across the mesh: every shard
            # prunes with the full per-vertex bound vector
            landmarks = landmarks.placed(
                NamedSharding(self.mesh, PartitionSpec()))
        self.landmarks = landmarks

    def run_batch(self, sources, goal: str = "tree", goal_params=None):
        """Same contract as :meth:`GraphEngine.run_batch` (leading slot
        axis, device arrays); padding vertices are sliced off."""
        lm = self.landmarks if goal == "p2p" else None
        dist, parent, metrics = sssp_distributed_batch(
            self.sg, np.asarray(sources, np.int32), self.mesh, ("graph",),
            version=self.version, fused_rounds=self.fused_rounds,
            capacity=self.capacity, max_iters=self.max_iters,
            alpha=self.alpha, beta=self.beta,
            policy=None if self.policy == "static" else self.policy,
            goal=goal, goal_params=goal_params, backend=self.backend,
            blocked=self.blocked, landmarks=lm)
        return dist[:, :self.n], parent[:, :self.n], metrics


class RegistryStats:
    """Counter-backed registry stats: the same ``stats.hits`` attribute
    surface as the old plain dataclass, but every field is a live
    read-through of a :class:`~repro.obs.metrics.MetricsRegistry` counter
    (``sssp_registry_<field>_total``), so the legacy accessors and the
    metrics snapshot/exposition can never disagree."""

    FIELDS = ("hits", "misses", "builds", "evictions", "build_waits")

    _HELP = {
        "hits": "Engine-cache lookups served from the cache",
        "misses": "Engine-cache lookups that required a build",
        "builds": "Engines built (cold or rebuild after re-register)",
        "evictions": "Engines dropped by LRU capacity pressure",
        "build_waits": "Lookups that waited on another thread's build",
    }

    def __init__(self, metrics):
        self._counters = {
            f: metrics.counter(f"sssp_registry_{f}_total", help=self._HELP[f])
            for f in self.FIELDS}

    def inc(self, field: str, amount: int = 1) -> None:
        self._counters[field].inc(amount)

    @property
    def hits(self) -> int:
        return self._counters["hits"].value

    @property
    def misses(self) -> int:
        return self._counters["misses"].value

    @property
    def builds(self) -> int:
        return self._counters["builds"].value

    @property
    def evictions(self) -> int:
        return self._counters["evictions"].value

    @property
    def build_waits(self) -> int:
        return self._counters["build_waits"].value

    def as_dict(self) -> dict:
        vals = {f: self._counters[f].value for f in self.FIELDS}
        total = vals["hits"] + vals["misses"]
        return {**vals,
                "hit_rate": vals["hits"] / total if total else 1.0}


class GraphRegistry:
    """LRU cache of serving engines over registered graph specs.

    Thread-safe: the LRU state is guarded by a short internal lock, and
    cold builds run outside it behind per-key futures — concurrent
    lookups of the *same* key share one build, lookups of other keys
    (notably already-built engines) never wait (see module docstring).

    ``shard_threshold_n`` / ``shard_threshold_m`` select the engine tier:
    a registered ``HostGraph`` at or above either threshold is served by
    a :class:`ShardedGraphEngine` over ``shard_devices`` (default: every
    local device); smaller graphs get the single-device
    :class:`GraphEngine` (optionally device-affine, see :meth:`engine`).
    ``register(..., tier=...)`` overrides per graph.  ``shard_backend``
    is the sharded tier's default relaxation backend; a per-lookup
    ``backend`` of ``blocked``/``blocked_pallas`` overrides it (the two
    tiers share one name axis, so a blocked-configured router serves
    blocked engines on both).

    **Generations.**  Every :meth:`register` bumps the gid's generation
    counter; engines record the generation they were built from.
    Invalidation listeners (:meth:`add_invalidation_listener`) fire after
    each re-register so a router can rebuild already-placed replicas
    eagerly instead of letting the next query pay the cold build.
    """

    def __init__(self, capacity: Optional[int] = None, *,
                 config: Optional[EngineConfig] = None,
                 backend: Optional[str] = None,
                 alpha: Optional[float] = None, beta: Optional[float] = None,
                 shard_threshold_n: Optional[int] = None,
                 shard_threshold_m: Optional[int] = None,
                 shard_devices=None, shard_version: Optional[str] = None,
                 shard_backend: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tuned=None, landmark_dir=None,
                 result_cache_capacity: int = 8, **backend_opts):
        # the config is the one option surface — loose kwargs (other than
        # capacity, which sizes this cache) must stay unset alongside it;
        # from_loose is the shared sentinel gate, so loose kwargs build
        # the very config the registry would have been given
        config = EngineConfig.from_loose(
            config, "registry",
            # the loose default pins the sharded tier to segment_min (no
            # blocked derivation), so the stored config agrees with this
            # registry's behavior
            defaults={"shard_backend": "segment_min"},
            backend=backend, alpha=alpha, beta=beta,
            shard_threshold_n=shard_threshold_n,
            shard_threshold_m=shard_threshold_m,
            shard_version=shard_version, shard_backend=shard_backend,
            devices=shard_devices, **backend_opts)
        config.validate_serving()
        backend_opts = {}
        for name in ("block_v", "tile_e", "use_kernel"):
            v = getattr(config, name)
            if v is not None:
                backend_opts[name] = v
        backend_opts["interpret"] = config.interpret
        if capacity is None:
            capacity = config.registry_capacity
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.config = config
        self.default_backend = relax.get_backend(config.backend).name
        self.alpha = config.alpha
        self.beta = config.beta
        self.backend_opts = backend_opts
        self.shard_threshold_n = config.shard_threshold_n
        self.shard_threshold_m = config.shard_threshold_m
        shard_devices = resolve_devices(config.devices)
        self.shard_devices = tuple(shard_devices) if shard_devices else None
        self.shard_version = config.shard_version
        self.shard_backend = config.effective_shard_backend
        # engine-variant knobs ride the config end-to-end (nothing a
        # resolve()-accepted config declares is silently dropped);
        # fused_rounds serves both tiers — the blocked single-device
        # megakernel and the sharded engines' round grouping / waves
        self.fused_rounds = config.fused_rounds
        self.shard_capacity = config.compact_capacity
        self.max_iters = config.max_iters
        self._lock = threading.RLock()
        self._specs: Dict[str, GraphSpec] = {}
        self._tiers: Dict[str, str] = {}
        self._gens: Dict[str, int] = {}
        self._listeners: list = []
        self._engines: "collections.OrderedDict[tuple, object]" \
            = collections.OrderedDict()
        self._building: Dict[tuple, Future] = {}
        # per-gid ALT landmark sets (see repro.core.landmarks): built
        # once per (gid, generation, params) and shared by every engine
        # variant of the gid — backend/device replicas reuse one build
        self._landmark_sets: Dict[str, LandmarkSet] = {}
        # the metrics registry is the shared sink for the whole serving
        # plane: schedulers/routers built on top of this registry default
        # to it, so one snapshot covers every layer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = RegistryStats(self.metrics)
        # offline-tuned per-gid configs (see repro.tune): a TunedStore or
        # a path to one; consulted at engine build, never on the hot path
        if tuned is not None and not hasattr(tuned, "apply"):
            from ..tune.store import TunedStore
            tuned = TunedStore(tuned)
        self.tuned = tuned
        self._tuned_builds = self.metrics.counter(
            "sssp_registry_tuned_builds_total",
            help="Engines built with a TunedStore override applied")
        # on-disk LandmarkSet cache (next to tuned configs): files keyed
        # by gid + graph fingerprint + build params, so a cold start on
        # an unchanged graph skips the batched landmark tree solve
        self._landmark_dir = (os.fspath(landmark_dir)
                              if landmark_dir is not None else None)
        self._lm_disk = {
            op: self.metrics.counter(
                f"sssp_landmarks_disk_{op}_total",
                help=f"LandmarkSet disk-cache {op}")
            for op in ("loads", "saves")}
        # streaming deltas (repro.delta): per-gid cumulative directed-edit
        # fraction + whether every delta so far was increase/remove-only
        # (the condition for stale-landmark admissibility), and a bounded
        # per-gid cache of full-tree solve states that apply_delta
        # *repairs* instead of evicting
        if result_cache_capacity < 1:
            raise ValueError("result_cache_capacity must be >= 1")
        self.result_cache_capacity = result_cache_capacity
        self._delta_frac: Dict[str, float] = {}
        self._delta_safe: Dict[str, bool] = {}
        self._result_cache: Dict[str, "collections.OrderedDict"] = {}
        self._delta_counters = {
            name: self.metrics.counter(f"sssp_delta_{name}_total", help=h)
            for name, h in (
                ("applied", "Edge-delta batches applied"),
                ("edges", "Directed edge edits applied"),
                ("layout_patches", "Cached engines patched in place"),
                ("repaired", "Cached solve states incrementally repaired"),
                ("reseeded", "Frontier vertices re-seeded by repairs"),
                ("landmarks_kept",
                 "LandmarkSets kept (stale) within the staleness budget"),
                ("landmarks_dropped",
                 "LandmarkSets dropped by deltas beyond the budget"),
            )}

    # ------------------------------------------------------------------
    # specs + tiers
    # ------------------------------------------------------------------

    def register(self, gid: str, graph: GraphSpec, *,
                 tier: Optional[str] = None) -> None:
        """Register (or replace) a graph spec; drops any cached engines
        built from the previous spec.  ``tier`` forces ``"single"`` or
        ``"sharded"``; default auto-selects by the shard thresholds
        (factory specs default to ``"single"`` — their size is unknown
        until built, so pass ``tier="sharded"`` explicitly)."""
        if not (isinstance(graph, (HostGraph, DeviceGraph))
                or callable(graph)):
            raise TypeError(
                f"expected HostGraph/DeviceGraph or factory for {gid!r}, "
                f"got {type(graph)}")
        if tier not in (None, "single", "sharded"):
            raise ValueError(f"tier must be 'single' or 'sharded', "
                             f"got {tier!r}")
        if tier is None:
            tier = "single"
            if isinstance(graph, (HostGraph, DeviceGraph)):
                n, m = int(graph.n), int(graph.m)
                if ((self.shard_threshold_n is not None
                     and n >= self.shard_threshold_n)
                        or (self.shard_threshold_m is not None
                            and m >= self.shard_threshold_m)):
                    tier = "sharded"
        with self._lock:
            replaced = gid in self._specs
            self._specs[gid] = graph
            self._tiers[gid] = tier
            self._gens[gid] = gen = self._gens.get(gid, 0) + 1
            for key in [k for k in self._engines if k[0] == gid]:
                del self._engines[key]
            # the ALT artifact belongs to the replaced spec: a rebuild
            # against the new spec is forced by the generation stamp,
            # dropping eagerly just frees the [L, N] matrix sooner
            self._landmark_sets.pop(gid, None)
            # a fresh spec resets the delta ledger and the repairable
            # result cache (cached states belong to the replaced graph)
            self._delta_frac.pop(gid, None)
            self._delta_safe.pop(gid, None)
            self._result_cache.pop(gid, None)
            # detach in-flight builds of the old spec: lookups from here
            # on start a fresh build of the new spec instead of attaching
            # to a stale future (the old build's owner only resolves its
            # own future — pre-replacement waiters — and the spec guard
            # below keeps its stale engine out of the cache)
            for key in [k for k in self._building if k[0] == gid]:
                del self._building[key]
            listeners = []
            if replaced:
                live = []
                for ref in self._listeners:
                    cb = ref()
                    if cb is not None:       # drop dead (collected) owners
                        live.append(ref)
                        listeners.append(cb)
                self._listeners = live
        # outside the lock: listeners typically rebuild engines (which
        # re-enter the registry); a first registration has no replicas to
        # refresh, so only *re*-registrations notify
        for cb in listeners:
            cb(gid, gen)

    def generation(self, gid: str) -> int:
        """Spec generation of ``gid`` (bumped by every :meth:`register`)."""
        with self._lock:
            if gid not in self._gens:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            return self._gens[gid]

    def add_invalidation_listener(self, cb) -> None:
        """Call ``cb(gid, generation)`` after every re-``register`` of an
        existing gid (in the registering thread, outside the registry
        lock).  Exceptions propagate to the ``register`` caller.

        Bound methods are held via ``weakref`` so a discarded owner (a
        router the caller dropped) is unhooked automatically instead of
        being kept alive — and rebuilt for — forever; plain functions
        and lambdas are held strongly (the caller owns their lifetime).
        """
        try:
            ref = weakref.WeakMethod(cb)
        except TypeError:
            ref = _StrongRef(cb)
        with self._lock:
            self._listeners.append(ref)

    def tier(self, gid: str) -> str:
        """The engine tier (``"single"``/``"sharded"``) serving ``gid``."""
        with self._lock:
            if gid not in self._tiers:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            return self._tiers[gid]

    @property
    def gids(self) -> tuple:
        with self._lock:
            return tuple(self._specs)

    def cached_keys(self) -> tuple:
        """Currently built (gid, backend, placement) keys, LRU -> MRU."""
        with self._lock:
            return tuple(self._engines)

    # ------------------------------------------------------------------
    # engine lookup / build
    # ------------------------------------------------------------------

    def _resolve(self, gid: str, backend, device):
        with self._lock:      # RLock: atomic with a caller's locked section
            if self._tiers.get(gid) == "sharded":
                # sharded engines key on the *sharded* backend name
                # (segment_min / blocked): a blocked lookup builds a
                # blocked whole-mesh engine, every other lookup shares
                # the registry's default
                sb = (self.shard_backend if backend is None
                      else _shard_backend_name(backend))
                return (gid, sb, "sharded"), None
        backend = (relax.get_backend(backend).name if backend is not None
                   else self.default_backend)
        if device is None:
            return (gid, backend, None), None
        if isinstance(device, int):
            device = jax.devices()[device]
        return (gid, backend, ("dev", device.id)), device

    def peek(self, gid: str, backend: Optional[str] = None,
             device=None):
        """Return the cached engine or None — never builds, never waits,
        never touches LRU order or hit/miss stats (for lock-sensitive
        callers like the scheduler's batch-formation path)."""
        key, _ = self._resolve(gid, backend, device)
        with self._lock:
            return self._engines.get(key)

    def engine(self, gid: str, backend: Optional[str] = None, device=None):
        """Get-or-build the engine for ``(gid, backend, device)``.

        ``device`` pins the single-device tier's buffers to that jax
        device (an index or a ``Device``; None keeps jax's default).
        Sharded-tier gids ignore ``device`` — their one engine spans
        ``shard_devices``.  Marks the entry MRU.
        """
        with self._lock:
            # key and (spec, tier) must come from one consistent view: a
            # racing register(tier=...) between them could file an engine
            # of one tier under the other tier's key
            key, dev = self._resolve(gid, backend, device)
            if gid not in self._specs:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            eng = self._engines.get(key)
            if eng is not None:
                self.stats.inc("hits")
                self._engines.move_to_end(key)
                return eng
            self.stats.inc("misses")
            fut = self._building.get(key)
            owner = fut is None
            if owner:
                fut = Future()
                self._building[key] = fut
                spec = self._specs[gid]
                tier = self._tiers[gid]
                gen = self._gens[gid]
            else:
                # same-key build in flight: share it (wait off-lock)
                self.stats.inc("build_waits")
        if not owner:
            return fut.result()
        # we own the build: run it outside the lock so other keys' lookups
        # (and producers) proceed
        try:
            eng = self._build(gid, spec, key[1], dev, tier)
            eng.generation = gen
        except BaseException as exc:
            with self._lock:
                if self._building.get(key) is fut:   # not replaced by a
                    del self._building[key]          # re-register's fresh build
            fut.set_exception(exc)
            raise
        with self._lock:
            if self._building.get(key) is fut:
                del self._building[key]
            self.stats.inc("builds")
            if self._specs.get(gid) is spec:     # not re-registered mid-build
                self._engines[key] = eng
                self._engines.move_to_end(key)
                while len(self._engines) > self.capacity:
                    self._engines.popitem(last=False)
                    self.stats.inc("evictions")
        fut.set_result(eng)
        return eng

    # ------------------------------------------------------------------
    # ALT landmark sets
    # ------------------------------------------------------------------

    def landmark_set(self, gid: str, hg=None, *,
                     n_landmarks: Optional[int] = None,
                     strategy: Optional[str] = None) -> LandmarkSet:
        """Get-or-build the gid's ALT :class:`LandmarkSet`.

        The cache is per-gid and validated on every lookup against the
        spec generation and the build parameters: a re-``register`` (new
        generation) or a changed ``n_landmarks``/``landmark_strategy``
        (a tuned overlay, say) rebuilds; otherwise every engine variant
        of the gid — backends, device replicas, both tiers — shares one
        ``[L, N]`` build.  ``hg`` avoids re-invoking a factory spec when
        the caller already holds the host graph.
        """
        if n_landmarks is None:
            n_landmarks = self.config.n_landmarks
        if strategy is None:
            strategy = self.config.landmark_strategy
        with self._lock:
            if gid not in self._specs:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            gen = self._gens[gid]
            spec = self._specs[gid]
            lm = self._landmark_sets.get(gid)
            if (lm is not None and lm.generation == gen
                    and lm.params() == (min(n_landmarks, int(lm.D.shape[1])),
                                        strategy)):
                return lm
        # build outside the lock (a tree-solve batch over the landmarks)
        if hg is None:
            hg = spec() if callable(spec) else spec
        path = self._landmark_path(gid, hg, n_landmarks, strategy)
        if path is not None and os.path.exists(path):
            # disk hit: the filename's graph fingerprint just matched, so
            # the saved set was built for this exact graph + params
            lm = dataclasses.replace(landmarks_mod.load(path),
                                     generation=gen)
            self._lm_disk["loads"].inc()
        else:
            with profiling.annotate(f"repro:landmark_build:{gid}"):
                lm = build_landmarks(hg, n_landmarks, strategy,
                                     generation=gen)
            if path is not None:
                os.makedirs(os.path.dirname(path), exist_ok=True)
                landmarks_mod.save(lm, path)
                self._lm_disk["saves"].inc()
        with self._lock:
            if self._gens.get(gid) == gen:      # not re-registered mid-build
                self._landmark_sets[gid] = lm
        return lm

    def _landmark_path(self, gid, hg, n_landmarks, strategy):
        """Disk-cache path for a gid's LandmarkSet (None when no
        ``landmark_dir``).  Keyed by graph fingerprint + build params —
        any delta moves the fingerprint, so a patched graph simply never
        matches the old file and rebuilds (then saves) a fresh one."""
        if self._landmark_dir is None:
            return None
        from ..tune.store import graph_fingerprint
        safe_gid = "".join(c if c.isalnum() or c in "-_" else "_"
                           for c in gid)
        k = min(int(n_landmarks), int(hg.n))
        return os.path.join(
            self._landmark_dir,
            f"landmarks_{safe_gid}_{graph_fingerprint(hg)}"
            f"_{k}_{strategy}.npz")

    def _build(self, gid, spec, backend, device, tier):
        with profiling.annotate(f"repro:engine_build:{gid}:{tier}"):
            return self._build_inner(gid, spec, backend, device, tier)

    def _build_inner(self, gid, spec, backend, device, tier):
        hg = spec() if callable(spec) else spec
        # per-gid tuned overlay: only the perf fields move (TUNED_FIELDS);
        # a stale fingerprint or an overlay this config can't carry falls
        # back inside TunedStore.apply, so the build never fails on it
        cfg = self.config
        if self.tuned is not None:
            # a graph still inside its delta staleness budget keeps its
            # tuned overlay (perf-only, bitwise-gated) even though the
            # fingerprint moved with the patch
            with self._lock:
                frac = self._delta_frac.get(gid, 0.0)
            stale_ok = 0.0 < frac <= self.config.delta_staleness_budget
            tuned_cfg = self.tuned.apply(gid, hg, cfg, n=int(hg.n),
                                         m=int(hg.m), allow_stale=stale_ok)
            if tuned_cfg != cfg:
                cfg = tuned_cfg
                self._tuned_builds.inc()
        lm = None
        if cfg.use_alt:
            lm = self.landmark_set(gid, hg, n_landmarks=cfg.n_landmarks,
                                   strategy=cfg.landmark_strategy)
        if tier == "sharded":
            # only the blocked layout's geometry opts apply mesh-side
            blocked_opts = {k: v for k, v in self.backend_opts.items()
                            if k in ("block_v", "tile_e", "use_kernel",
                                     "interpret")}
            if backend == "blocked":
                for nm in ("block_v", "tile_e"):
                    v = getattr(cfg, nm)
                    if v is None:
                        blocked_opts.pop(nm, None)
                    else:
                        blocked_opts[nm] = v
            return ShardedGraphEngine(gid, hg, cfg.alpha, cfg.beta,
                                      devices=self.shard_devices,
                                      version=self.shard_version,
                                      fused_rounds=cfg.fused_rounds,
                                      capacity=cfg.compact_capacity,
                                      max_iters=self.max_iters,
                                      backend=backend, policy=cfg.policy,
                                      landmarks=lm, **blocked_opts)
        backend_opts = dict(self.backend_opts)
        is_blocked = relax.get_backend(backend).name == "blocked_pallas"
        if is_blocked:
            for nm in ("block_v", "tile_e"):
                v = getattr(cfg, nm)
                if v is None:
                    backend_opts.pop(nm, None)
                else:
                    backend_opts[nm] = v
        # fused_rounds is a blocked-megakernel knob on the single-device
        # tier; a per-lookup segment_min backend must not inherit it
        fused = cfg.fused_rounds if is_blocked else 0
        return GraphEngine(gid, hg, backend, cfg.alpha, cfg.beta,
                           device=device, max_iters=self.max_iters,
                           fused_rounds=fused, policy=cfg.policy,
                           landmarks=lm, p2p_mode=cfg.p2p_mode,
                           **backend_opts)

    def evict(self, gid: str, backend: Optional[str] = None,
              device=None) -> bool:
        """Drop a cached engine (the spec stays registered)."""
        key, _ = self._resolve(gid, backend, device)
        with self._lock:
            return self._engines.pop(key, None) is not None

    # ------------------------------------------------------------------
    # streaming deltas (repro.delta): patch + repair instead of rebuild
    # ------------------------------------------------------------------

    def delta_frac(self, gid: str) -> float:
        """Cumulative directed-edit fraction (edits / m) the gid has
        absorbed since its last :meth:`register` (the staleness ledger)."""
        with self._lock:
            return self._delta_frac.get(gid, 0.0)

    def cache_result(self, gid: str, source: int, dist, parent) -> None:
        """Cache a **full-tree** solve state for delta repair.

        :meth:`apply_delta` repairs cached states in place instead of
        evicting them, keeping them bitwise-identical to from-scratch
        solves on the patched graph.  Tree goals only: an early-exit
        goal (p2p/bounded/knear) stops with tentative entries beyond its
        horizon, and repairing such a state would relax it toward the
        full-tree fixpoint — no longer the early-exit answer.  LRU per
        gid, at most ``result_cache_capacity`` sources.
        """
        dist = np.asarray(dist, np.float32).copy()
        parent = np.asarray(parent, np.int32).copy()
        with self._lock:
            if gid not in self._specs:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            cache = self._result_cache.setdefault(
                gid, collections.OrderedDict())
            cache[int(source)] = (dist, parent)
            cache.move_to_end(int(source))
            while len(cache) > self.result_cache_capacity:
                cache.popitem(last=False)

    def cached_result(self, gid: str, source: int):
        """``(dist, parent)`` numpy arrays for a cached tree solve, or
        ``None``; marks the entry MRU."""
        with self._lock:
            cache = self._result_cache.get(gid)
            if cache is None or int(source) not in cache:
                return None
            cache.move_to_end(int(source))
            return cache[int(source)]

    def apply_delta(self, gid: str, edits) -> dict:
        """Apply an :class:`~repro.delta.EdgeDelta` to ``gid`` *in place*.

        The streaming counterpart of :meth:`register`: one host-side
        patch (:func:`repro.delta.patch_host`) is shared by every cached
        engine of the gid — each backend/placement/tier gets its layout
        patched rather than rebuilt (single-device blocked layouts
        through :func:`repro.delta.patch_blocked_with`, sharded slabs
        through :func:`repro.delta.patch_sharded_with`; patched layouts
        are bitwise-identical to a from-scratch rebuild).  Cached tree
        states (:meth:`cache_result`) are incrementally repaired,
        bitwise-identical to from-scratch solves on the patched graph.

        Unlike :meth:`register`, the generation is **not** bumped and
        invalidation listeners do **not** fire: a router's placed
        replicas stay placed and receive the patched engines (one patch,
        N placements — no per-replica re-bucketing).  Engines are
        replaced as patched shallow copies, so an in-flight batch on the
        old object stays internally consistent.

        Perf artifacts follow ``config.delta_staleness_budget``
        (cumulative directed edits / m): the gid's ALT LandmarkSet
        survives increase/remove-only deltas within budget — marked
        ``stale``, which drops it to forward-difference bounds (old
        distances stay admissible lower bounds, see
        :class:`~repro.core.landmarks.LandmarkSet`) — and TunedStore
        overlays keep applying within budget.  Beyond the budget (or
        after any add/decrease) the LandmarkSet is dropped and rebuilds
        lazily.  Holds the registry lock for the patch; returns a report
        dict (``n_edits``/``engines_patched``/``results_repaired``/
        ``delta_frac``/``landmarks``/``host``/``applied``).
        """
        with self._lock:
            if gid not in self._specs:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            spec = self._specs[gid]
            if callable(spec):
                spec = spec()
            if isinstance(spec, DeviceGraph):
                spec = HostGraph(
                    n=int(spec.n), src=np.asarray(spec.src),
                    dst=np.asarray(spec.dst), w=np.asarray(spec.w),
                    row_ptr=np.asarray(spec.row_ptr),
                    deg=np.asarray(spec.deg), rtow=np.asarray(spec.rtow),
                    max_w=float(spec.max_w))
            old_host = spec
            with profiling.annotate(f"repro:apply_delta:{gid}"):
                new_host, applied = patch_host(old_host, edits)
                self._specs[gid] = new_host
                # in-flight builds saw the old spec; the spec-identity
                # guard in engine() keeps their product out of the cache
                for key in [k for k in self._building if k[0] == gid]:
                    del self._building[key]
                frac = (self._delta_frac.get(gid, 0.0)
                        + applied.n_edits / max(old_host.m, 1))
                self._delta_frac[gid] = frac
                safe = self._delta_safe.get(gid, True) and applied.safe_stale
                self._delta_safe[gid] = safe
                keep_lm = safe and frac <= self.config.delta_staleness_budget
                lm = self._landmark_sets.get(gid)
                if lm is not None:
                    if keep_lm:
                        self._landmark_sets[gid] = dataclasses.replace(
                            lm, stale=True)
                        self._delta_counters["landmarks_kept"].inc()
                    else:
                        self._landmark_sets.pop(gid, None)
                        self._delta_counters["landmarks_dropped"].inc()
                n_patched = 0
                for key in [k for k in self._engines if k[0] == gid]:
                    eng = self._patch_engine(self._engines[key], old_host,
                                             new_host, applied, keep_lm)
                    self._engines[key] = eng    # same key: LRU position kept
                    n_patched += 1
                n_repaired = 0
                cache = self._result_cache.get(gid)
                if cache:
                    g_new = new_host.to_device()
                    for source in list(cache):
                        dist, parent = cache[source]
                        d_i, p_i, f0, st = repair_state(new_host, dist,
                                                        parent, applied)
                        d2, p2, _ = repair_relax(g_new, d_i, p_i, f0,
                                                 max_iters=self.max_iters)
                        cache[source] = (np.asarray(d2), np.asarray(p2))
                        self._delta_counters["reseeded"].inc(st.n_seeds)
                        n_repaired += 1
                    self._delta_counters["repaired"].inc(n_repaired)
                self._delta_counters["applied"].inc()
                self._delta_counters["edges"].inc(applied.n_edits)
                self._delta_counters["layout_patches"].inc(n_patched)
        return {"gid": gid, "n_edits": applied.n_edits,
                "engines_patched": n_patched,
                "results_repaired": n_repaired, "delta_frac": frac,
                "landmarks": ("stale" if lm is not None and keep_lm
                              else "dropped" if lm is not None else "none"),
                "host": new_host, "applied": applied}

    def _patch_engine(self, eng, old_host, new_host, applied, keep_lm):
        """Patched shallow copy of a cached engine (either tier).

        The copy shares the hint state (eccentricity estimates are
        scheduling heuristics; a small delta barely moves them) and gets
        new graph/layout buffers; the original object is left untouched
        for any batch already running on it.
        """
        eng = copy.copy(eng)
        eng.host = new_host
        eng.deg = np.asarray(new_host.deg)
        if eng.landmarks is not None:
            eng.landmarks = (dataclasses.replace(eng.landmarks, stale=True)
                             if keep_lm else None)
        if eng.tier == "sharded":
            sg = patch_sharded_with(eng.sg, new_host, applied)
            eng.sg = ShardedGraph(*(
                jax.device_put(x, NamedSharding(eng.mesh, s))
                for x, s in zip(sg, graph_specs("graph"))))
            if eng.blocked is not None:
                # per-shard blocked slabs: full re-bucket for now (the
                # uniform-n_tiles stacked layout couples every shard's
                # tile budget; an in-place patcher is a follow-up)
                _, bmeta = eng.blocked
                arrays, bmeta = shard_blocked(
                    new_host, len(eng.devices), block_v=bmeta.block_v,
                    tile_e=bmeta.tile_e, use_kernel=bmeta.use_kernel,
                    interpret=bmeta.interpret)
                arrays = type(arrays)(*(
                    jax.device_put(x, NamedSharding(eng.mesh, s))
                    for x, s in zip(arrays, blocked_specs("graph"))))
                eng.blocked = (arrays, bmeta)
            return eng
        g = new_host.to_device()
        if eng.device is not None:
            g = jax.device_put(g, eng.device)
        eng.g = g
        if eng.backend.name == "blocked_pallas":
            layout = patch_blocked_with(eng.layout, old_host, new_host,
                                        applied)
            if eng.device is not None:
                layout = jax.device_put(layout, eng.device)
            eng.layout = layout
        else:
            eng.layout = eng.backend.prepare(eng.g)
        return eng

    # ------------------------------------------------------------------
    # warmup
    # ------------------------------------------------------------------

    def warmup(self, gids=None, *, backend: Optional[str] = None,
               device=None, kinds=("tree",), batch_sizes=(1,)):
        """Pre-pay engine builds and per-(graph, kind, batch-width) jit
        compiles before traffic arrives.

        Runs one dummy batch per (gid, kind, batch size) — the jit cache
        is keyed on the static goal kind and the batch width, so pass the
        scheduler's ``max_batch`` in ``batch_sizes`` for the compiles to
        be the ones traffic will hit.  Returns one row dict per dummy
        batch with ``build_s`` (engine build, attributed to its first
        row) and ``compile_s`` wall times — the serving benchmark reports
        these as the warmup cost.
        """
        if isinstance(gids, str):
            gids = [gids]
        gids = list(self.gids) if gids is None else list(gids)
        for kind in kinds:
            if kind not in GOALS:
                raise ValueError(f"unknown warmup kind {kind!r}; "
                                 f"expected one of {GOALS}")
        rows = []
        for gid in gids:
            t0 = time.perf_counter()
            eng = self.engine(gid, backend, device=device)
            build_s = time.perf_counter() - t0
            src = int(np.argmax(eng.deg))       # a vertex with edges
            for kind in kinds:
                for bs in batch_sizes:
                    bs = int(bs)
                    gp = {"tree": None, "p2p": [src] * bs,
                          "bounded": [0.0] * bs, "knear": [1] * bs}[kind]
                    t0 = time.perf_counter()
                    out = eng.run_batch([src] * bs, goal=kind,
                                        goal_params=gp)
                    jax.block_until_ready(out[0])
                    rows.append({"gid": gid, "tier": eng.tier, "kind": kind,
                                 "batch": bs, "build_s": build_s,
                                 "compile_s": time.perf_counter() - t0})
                    build_s = 0.0               # attribute the build once
        return rows

