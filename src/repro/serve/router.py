"""Multi-device serving plane: a router over per-device schedulers.

PR 2's stack drove exactly one device with one worker thread.  This
module scales it across a JAX device mesh the way the stepping
literature scales across cores (Dong et al., arXiv:2105.06145): keep
every execution unit busy.

::

                         QueryRouter.submit(query)
                                   |
                 placement (stickiness) + least-outstanding-work
                 /                 |                  \\
        QueryScheduler(dev0) QueryScheduler(dev1) ... QueryScheduler(devP-1)
                 |                 |                  |
          GraphEngine@dev0   GraphEngine@dev1   GraphEngine@devP-1
                 \\_________________|_________________/
                                   |
            sharded-tier gids ->  "mesh" QueryScheduler
                                   |
                    ShardedGraphEngine (shard_map, whole mesh)

* **Placement + stickiness** — the first query for a graph places it on
  the least-loaded device (fewest outstanding tickets, ties broken by
  fewest placed graphs); later queries stick to that device so its
  engine cache, jit cache, and batch hints stay warm.  A graph
  replicated on several devices routes each query to its
  least-outstanding replica.
* **Hot-graph replication** — when one device's outstanding depth
  dominates the pool (``replicate_factor`` x the mean of the others, and
  at least ``replicate_min_depth``), the router replicates that device's
  hottest graph onto the least-loaded device; the registry builds the
  replica engine there on first use (outside every lock).
* **Replica decay** — the inverse move: routed traffic is accounted in
  windows of ``decay_window`` placed queries, and a replica whose share
  of its graph's window traffic stays at ~0 (``<= decay_share``) for
  ``decay_windows`` consecutive windows is removed from the placement
  (its cached engine then ages out of the registry LRU naturally).  The
  replica carrying the graph's largest share is never decayed, so every
  gid keeps >= 1 placement.
* **Engine tiers** — graphs the registry classifies as sharded
  (:class:`~repro.serve.registry.ShardedGraphEngine`) span the whole
  mesh, so they bypass per-device placement and run on a dedicated
  ``"mesh"`` scheduler through the identical ``run_batch`` interface.

Each per-device scheduler double-buffers (dispatch batch *k+1* while
host-finalizing batch *k* — see :mod:`repro.serve.scheduler`), so with P
devices up to P batches compute while P hosts finalize.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import jax

from ..core.config import EngineConfig, resolve_devices
from ..obs.metrics import MetricsRegistry
from .queries import Query
from .registry import GraphRegistry
from .scheduler import QueryScheduler

__all__ = ["QueryRouter"]


class QueryRouter:
    """Route queries across a pool of per-device :class:`QueryScheduler` s.

    ``devices`` defaults to every local jax device (one scheduler each);
    passing an explicit list also allows repeating a device, which is
    how the logic is unit-tested on single-device hosts.  All other
    knobs are forwarded to the per-device schedulers (``max_pending``
    bounds *each* device queue — total admission capacity is
    ``P * max_pending``).

    ``config`` accepts an :class:`~repro.core.config.EngineConfig` in
    place of the loose serving kwargs (``max_batch`` / ``max_pending`` /
    ``ecc_batching``, and ``devices`` when the config pins them) — the
    :class:`repro.api.Solver` routed tier's path.

    ``decay_window``/``decay_share``/``decay_windows`` control replica
    decay (see module docstring); ``decay_window=0`` disables it.
    ``decay_min_traffic`` gates decay on a graph's absolute window
    traffic (a gid below it keeps its placement), and replicas
    pre-placed by :meth:`plan_placement` are exempt from decay until
    their forecast traffic actually arrives.
    """

    def __init__(self, registry: GraphRegistry, *, devices=None,
                 config: Optional[EngineConfig] = None,
                 max_batch: Optional[int] = None,
                 backend: Optional[str] = None,
                 admit_window: Optional[int] = None,
                 ecc_batching: Optional[bool] = None,
                 max_pending: Optional[int] = None,
                 feedback: bool = True,
                 replicate_factor: float = 4.0,
                 replicate_min_depth: int = 16,
                 decay_window: int = 256,
                 decay_share: float = 0.05,
                 decay_windows: int = 3,
                 decay_min_traffic: int = 1,
                 clock=time.monotonic,
                 metrics: Optional[MetricsRegistry] = None):
        user_config = config is not None
        config = EngineConfig.from_loose(
            config, "router", max_batch=max_batch, backend=backend,
            max_pending=max_pending, ecc_batching=ecc_batching)
        max_batch = config.max_batch
        max_pending = config.max_pending
        ecc_batching = config.ecc_batching
        if user_config:
            # the registry already carries the config's backend as its
            # default; the router-level override stays unset so lookups
            # defer to it
            backend = None
            if devices is None:
                devices = resolve_devices(config.devices)
        devices = (list(devices) if devices is not None
                   else list(jax.devices()))
        if not devices:
            raise ValueError("need at least one device")
        if replicate_factor < 1.0:
            raise ValueError("replicate_factor must be >= 1")
        if decay_window < 0 or decay_windows < 1 or decay_share < 0 \
                or decay_min_traffic < 0:
            raise ValueError("decay_window must be >= 0, decay_windows "
                             ">= 1, decay_share >= 0, decay_min_traffic "
                             ">= 0")
        self.registry = registry
        self.devices = devices
        self.backend = backend
        self.max_batch = max_batch
        self.replicate_factor = replicate_factor
        self.replicate_min_depth = replicate_min_depth
        # one metrics registry for the whole plane: the router, every
        # per-device scheduler, and the graph registry all write to it,
        # so a single snapshot/exposition covers every layer
        self.metrics = metrics if metrics is not None else registry.metrics
        kw = dict(max_batch=max_batch, backend=backend,
                  admit_window=admit_window, ecc_batching=ecc_batching,
                  max_pending=max_pending, feedback=feedback,
                  clock=clock, metrics=self.metrics)
        self.schedulers = [
            QueryScheduler(registry, device=d, name=f"dev{i}", **kw)
            for i, d in enumerate(devices)]
        # sharded-tier engines span the whole mesh; one scheduler drives
        # them so per-device queues stay device-sized
        self.mesh_scheduler = QueryScheduler(registry, device=None,
                                             name="mesh", **kw)
        self._lock = threading.Lock()
        self._placement: Dict[str, List[int]] = {}
        self._load = [0] * len(self.schedulers)      # outstanding tickets
        self._n_placed = [0] * len(self.schedulers)  # graphs placed
        self._gid_load: Dict[Tuple[int, str], int] = {}
        self._mesh_gids: set = set()                 # sharded gids served
        # replica decay accounting (per routing window)
        self.decay_window = decay_window
        self.decay_share = decay_share
        self.decay_windows = decay_windows
        self.decay_min_traffic = decay_min_traffic
        self._window_routed = 0
        self._window_traffic: Dict[Tuple[int, str], int] = {}
        self._cold_streak: Dict[Tuple[int, str], int] = {}
        # capacity-planned replicas (plan_placement): protected from
        # share-based decay until they have carried real traffic
        self._planned: set = set()
        self._c_routed = self.metrics.counter(
            "sssp_router_routed_total", help="Queries routed")
        self._c_replications = self.metrics.counter(
            "sssp_router_replications_total",
            help="Hot-graph replications onto an extra device")
        self._c_rebuilds = self.metrics.counter(
            "sssp_router_rebuilds_total",
            help="Replica engines rebuilt after a spec re-register")
        self._c_decays = self.metrics.counter(
            "sssp_router_decays_total",
            help="Cold replicas removed from a graph's placement")
        # replica consistency: a re-register() drops the cached engines,
        # but an already-placed replica would otherwise serve its next
        # query from a cold build; rebuild every replica eagerly instead
        registry.add_invalidation_listener(self._rebuild_replicas)

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    # legacy counter attributes: read-throughs of the metrics series
    @property
    def n_routed(self) -> int:
        return self._c_routed.value

    @property
    def n_replications(self) -> int:
        return self._c_replications.value

    @property
    def n_rebuilds(self) -> int:
        return self._c_rebuilds.value

    @property
    def n_decays(self) -> int:
        return self._c_decays.value

    def _all_schedulers(self):
        return self.schedulers + [self.mesh_scheduler]

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def _route_locked(self, gid: str) -> int:
        placed = self._placement.get(gid)
        if not placed:
            idx = min(range(len(self.schedulers)),
                      key=lambda i: (self._load[i], self._n_placed[i], i))
            self._placement[gid] = [idx]
            self._n_placed[idx] += 1
            return idx
        if len(placed) == 1:
            return placed[0]
        return min(placed, key=lambda i: (self._load[i], i))

    def _done(self, idx: int, gid: str) -> None:
        with self._lock:
            self._load[idx] = max(self._load[idx] - 1, 0)
            key = (idx, gid)
            left = self._gid_load.get(key, 0) - 1
            if left > 0:
                self._gid_load[key] = left
            else:
                self._gid_load.pop(key, None)

    def _maybe_replicate_locked(self) -> None:
        """Replicate the hottest graph off a dominating device."""
        if len(self.schedulers) < 2:
            return
        hot = max(range(len(self._load)), key=lambda i: self._load[i])
        depth = self._load[hot]
        if depth < self.replicate_min_depth:
            return
        others = [l for i, l in enumerate(self._load) if i != hot]
        if depth < self.replicate_factor * (sum(others) / len(others) + 1.0):
            return
        gids = [(c, g) for (i, g), c in self._gid_load.items() if i == hot]
        if not gids:
            return
        gid = max(gids)[1]
        cold = min(range(len(self._load)),
                   key=lambda i: (self._load[i], self._n_placed[i], i))
        placed = self._placement.setdefault(gid, [])
        if cold == hot or cold in placed:
            return
        placed.append(cold)
        self._n_placed[cold] += 1
        self._c_replications.inc()

    def _maybe_decay_locked(self) -> None:
        """Close one routing window; shrink placements of replicas whose
        traffic share stayed ~0 for ``decay_windows`` consecutive windows
        (the teardown counterpart of :meth:`_maybe_replicate_locked`)."""
        if not self.decay_window \
                or self._window_routed < self.decay_window:
            return
        gid_totals: Dict[str, int] = {}
        for (_, gid), c in self._window_traffic.items():
            gid_totals[gid] = gid_totals.get(gid, 0) + c
        for gid, placed in self._placement.items():
            total = gid_totals.get(gid, 0)
            if len(placed) < 2 or total < max(1, self.decay_min_traffic):
                # nothing to shrink / a cold or below-threshold gid keeps
                # its placement (decay reacts to *skew*, not absence)
                for i in placed:
                    self._cold_streak.pop((i, gid), None)
                continue
            shares = {i: self._window_traffic.get((i, gid), 0) / total
                      for i in placed}
            # the replica carrying the largest share survives always
            keep = max(placed, key=lambda i: (shares[i], -i))
            for i in list(placed):
                key = (i, gid)
                if key in self._planned:
                    # capacity-planned replica: forecast traffic hasn't
                    # arrived yet — protected until it carries a real
                    # share, then it competes like any other replica
                    if shares[i] > self.decay_share:
                        self._planned.discard(key)
                    self._cold_streak.pop(key, None)
                    continue
                if i != keep and shares[i] <= self.decay_share:
                    streak = self._cold_streak.get(key, 0) + 1
                    if streak >= self.decay_windows:
                        placed.remove(i)
                        self._n_placed[i] = max(self._n_placed[i] - 1, 0)
                        self._cold_streak.pop(key, None)
                        self._c_decays.inc()
                    else:
                        self._cold_streak[key] = streak
                else:
                    self._cold_streak.pop(key, None)
        self._window_traffic = {}
        self._window_routed = 0

    def _rebuild_replicas(self, gid: str, generation: int) -> None:
        """Registry invalidation hook: rebuild every placed replica of
        ``gid`` (and a served sharded-tier engine) at the new generation.

        Runs in the re-registering thread; each build goes through the
        registry's per-key build futures, so queries racing the rebuild
        simply share it instead of serving a second cold build.

        Streaming edits never reach this hook:
        :meth:`GraphRegistry.apply_delta` patches every cached engine in
        place — per-device replicas included, each under its existing
        ``(gid, backend, device)`` cache key — without bumping the
        generation or firing listeners.  One host-side patch serves all
        N placements; ``n_rebuilds`` stays flat across deltas (the
        rebuild-per-replica path is reserved for full re-registers).
        """
        try:
            tier = self.registry.tier(gid)
        except KeyError:
            return
        if tier == "sharded":
            with self._lock:
                served = gid in self._mesh_gids
            if served:
                self.registry.engine(gid, self.backend)
                self._c_rebuilds.inc()
            return
        with self._lock:
            idxs = list(self._placement.get(gid, ()))
        seen = set()
        for idx in idxs:
            dev = self.devices[idx]
            dev_key = getattr(dev, "id", dev)
            if dev_key in seen:     # duplicated devices share one engine
                continue
            seen.add(dev_key)
            self.registry.engine(gid, self.backend, device=dev)
            self._c_rebuilds.inc()

    def plan_placement(self, weights: Dict[str, float]) -> Dict[str, list]:
        """Pre-place graphs with replica counts proportional to expected
        load (capacity planning from historical/forecast traffic shares).

        Each gid gets ``max(1, round(P * weight / total))`` replicas
        (capped at P), assigned hottest-first onto the devices hosting
        the fewest graphs.  Combine with :meth:`warmup` so every replica
        engine is built + compiled before traffic; the dynamic
        replication path then only handles *unforecast* shifts.  Returns
        ``{gid: [scheduler names]}``.
        """
        total = float(sum(weights.values()))
        if total <= 0:
            raise ValueError("weights must sum to > 0")
        n_sch = len(self.schedulers)
        with self._lock:
            for gid, wt in sorted(weights.items(), key=lambda kv: -kv[1]):
                if self.registry.tier(gid) == "sharded":
                    continue          # spans the mesh already
                n_rep = max(1, min(n_sch, round(n_sch * wt / total)))
                placed = self._placement.setdefault(gid, [])
                while len(placed) < n_rep:
                    free = [i for i in range(n_sch) if i not in placed]
                    if not free:
                        break
                    idx = min(free, key=lambda i: (self._n_placed[i], i))
                    placed.append(idx)
                    self._n_placed[idx] += 1
                # the plan endorses this placement: protect it from
                # share-based decay until its forecast traffic shows up
                self._planned.update((i, gid) for i in placed)
            return {gid: [self.schedulers[i].name for i in idxs]
                    for gid, idxs in self._placement.items()}

    def submit(self, query: Query, *, priority: int = 0,
               deadline_s: Optional[float] = None):
        """Route and enqueue one query; returns the scheduler future.

        Raises :class:`~repro.serve.scheduler.QueueFull` when the target
        device's bounded queue is full (load shedding is per device —
        sticky traffic must not hide one hot device behind idle ones).
        """
        gid = query.gid
        try:
            tier = self.registry.tier(gid)
        except KeyError:
            # unknown gid: route to the least-loaded scheduler *without*
            # creating placement state (the engine lookup fails the future
            # loudly; phantom gids must not skew placement tie-breaking)
            with self._lock:
                idx = min(range(len(self.schedulers)),
                          key=lambda i: (self._load[i], i))
            self._c_routed.inc()
            return self.schedulers[idx].submit(query, priority=priority,
                                               deadline_s=deadline_s)
        if tier == "sharded":
            fut = self.mesh_scheduler.submit(query, priority=priority,
                                             deadline_s=deadline_s)
            self._c_routed.inc()
            with self._lock:
                self._mesh_gids.add(gid)
            return fut
        with self._lock:
            idx = self._route_locked(gid)
        fut = self.schedulers[idx].submit(query, priority=priority,
                                          deadline_s=deadline_s)
        self._c_routed.inc()
        with self._lock:
            self._load[idx] += 1
            self._gid_load[(idx, gid)] = \
                self._gid_load.get((idx, gid), 0) + 1
            self._window_routed += 1
            self._window_traffic[(idx, gid)] = \
                self._window_traffic.get((idx, gid), 0) + 1
            self._maybe_replicate_locked()
            self._maybe_decay_locked()
        # outside the router lock: a done future runs the callback inline
        fut.add_done_callback(lambda _f, i=idx, g=gid: self._done(i, g))
        return fut

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> None:
        """Start one background worker per device (plus the mesh one)."""
        for sch in self._all_schedulers():
            sch.start()

    def stop(self, cancel_pending: bool = False) -> None:
        for sch in self._all_schedulers():
            sch.stop(cancel_pending=cancel_pending)

    def drain(self, max_steps: int = 10_000) -> int:
        """Synchronously round-robin the pool until every queue empties
        (single-threaded alternative to :meth:`start`)."""
        steps = 0
        progress = True
        while progress and steps < max_steps:
            progress = False
            for sch in self._all_schedulers():
                if steps >= max_steps:
                    break
                if sch.step():
                    steps += 1
                    progress = True
        return steps

    # ------------------------------------------------------------------
    # warmup + stats
    # ------------------------------------------------------------------

    def warmup(self, gids=None, *, kinds=("tree",), batch_sizes=None):
        """Pre-place graphs and pre-pay their jit compiles before traffic.

        Each single-tier gid is placed (becoming its sticky device) and
        its engine built + compiled there via
        :meth:`GraphRegistry.warmup`; sharded-tier gids warm on the mesh.
        ``batch_sizes`` defaults to this router's ``max_batch`` so the
        compiles are exactly the ones traffic will hit.  Returns the
        registry warmup rows with the serving scheduler attached.
        """
        if batch_sizes is None:
            batch_sizes = (self.max_batch,)
        if isinstance(gids, str):
            gids = [gids]
        gids = list(self.registry.gids) if gids is None else list(gids)
        rows = []
        for gid in gids:
            if self.registry.tier(gid) == "sharded":
                with self._lock:
                    self._mesh_gids.add(gid)
                rs = self.registry.warmup([gid], backend=self.backend,
                                          kinds=kinds,
                                          batch_sizes=batch_sizes)
                for r in rs:
                    r["scheduler"] = self.mesh_scheduler.name
                rows.extend(rs)
                continue
            with self._lock:
                self._route_locked(gid)      # place if unplaced
                idxs = list(self._placement[gid])
            for idx in idxs:                 # warm every replica device
                rs = self.registry.warmup([gid], backend=self.backend,
                                          device=self.devices[idx],
                                          kinds=kinds,
                                          batch_sizes=batch_sizes)
                for r in rs:
                    r["scheduler"] = self.schedulers[idx].name
                rows.extend(rs)
        return rows

    def stats(self) -> dict:
        per = [sch.stats() for sch in self._all_schedulers()]
        n_batches = sum(s["n_batches"] for s in per)
        n_done = sum(s["n_done"] for s in per)
        with self._lock:
            placement = {gid: [self.schedulers[i].name for i in idxs]
                         for gid, idxs in self._placement.items()}
            return {
                "n_devices": self.n_devices,
                "n_routed": self.n_routed,
                "n_replications": self.n_replications,
                "n_rebuilds": self.n_rebuilds,
                "n_decays": self.n_decays,
                "n_batches": n_batches,
                "n_done": n_done,
                "n_expired": sum(s["n_expired"] for s in per),
                "rejected": sum(s["rejected"] for s in per),
                "pending": sum(s["pending"] for s in per),
                "occupancy": (n_done / (n_batches * self.max_batch)
                              if n_batches else 0.0),
                "placement": placement,
                "schedulers": per,
                "registry": self.registry.stats.as_dict(),
            }
