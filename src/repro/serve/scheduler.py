"""Async admission layer: thread-safe query queue -> fused engine batches.

Producers call :meth:`QueryScheduler.submit` from any thread and get a
``concurrent.futures.Future`` resolving to a
:class:`~repro.serve.queries.QueryResult`.  A batch step (driven either
synchronously via :meth:`step`/:meth:`drain` or by the background worker
started with :meth:`start`) then

1. **expires** tickets whose deadline passed (``DeadlineExceeded`` on the
   future) — deadline-aware admission;
2. orders the queue by ``(priority desc, deadline, FIFO seq)`` and picks
   the head-of-line ticket — priority-aware admission;
3. restricts an ``admit_window`` of queue-front tickets to the head's
   batch-compatibility key ``(gid, goal kind)`` (one compiled engine per
   batch), then fills the remaining slots with the window tickets whose
   **estimated stepping cost** is nearest the head's, so a vmapped batch
   is not dominated by one long-running outlier's rounds.  The estimate
   is the engine's ``batch_hint`` — landmark-BFS eccentricity blended
   (EMA) with *measured* per-source round counts this scheduler feeds
   back after every batch;
4. pads free slots by repeating slot 0 (static batch shape, no
   recompiles; padded results are discarded, never surfaced) and runs one
   fused ``sssp_batch`` goal query.

**Device affinity.**  A scheduler constructed with ``device=`` asks the
registry for engines pinned to that device — the multi-device router
(:mod:`repro.serve.router`) runs one such scheduler per device.

**Load shedding.**  With ``max_pending`` set, :meth:`submit` rejects at
submit time with :class:`QueueFull` once that many tickets queue
(counted in ``stats()["rejected"]``) instead of only expiring deadlines
after admission — bounded queues are what keep overload from turning
into unbounded latency.

**Double buffering.**  ``run_batch`` dispatches asynchronously and
returns device arrays; the background worker dispatches batch *k+1*
before forcing batch *k*'s results to the host, so host-side
finalization (path reconstruction, result shaping, future callbacks)
overlaps the device compute instead of stalling it.

The head of line is always admitted, so priority/FIFO progress is
starvation-free; the cost-hint grouping only chooses its *companions*.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Tuple

import numpy as np
import jax

from .queries import ExecutionPlan, Query, finalize, plan
from .registry import GraphRegistry
from ..obs.metrics import MetricsRegistry

__all__ = ["DeadlineExceeded", "QueueFull", "QueryScheduler"]


class DeadlineExceeded(Exception):
    """Raised on a query future whose deadline passed before admission."""


class QueueFull(Exception):
    """Raised by ``submit`` when the bounded admission queue is full."""


@dataclasses.dataclass
class _Ticket:
    seq: int
    query: Query
    plan: ExecutionPlan
    priority: int
    deadline: Optional[float]         # absolute monotonic time or None
    future: Future
    t_submit: float

    def sort_key(self):
        return (-self.priority,
                self.deadline if self.deadline is not None else float("inf"),
                self.seq)


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-not-finalized batch (the double buffer slot)."""
    batch: List[_Ticket]
    eng: object
    sources: np.ndarray               # real (unpadded) ticket sources
    dist: object                      # device arrays, possibly still computing
    parent: object
    metrics: object


class QueryScheduler:
    """Thread-safe admission queue over a :class:`GraphRegistry`."""

    def __init__(self, registry: GraphRegistry, *, max_batch: int = 8,
                 backend: Optional[str] = None,
                 admit_window: Optional[int] = None,
                 ecc_batching: bool = True,
                 device=None, name: Optional[str] = None,
                 max_pending: Optional[int] = None,
                 feedback: bool = True, feedback_gamma: float = 0.25,
                 clock=time.monotonic,
                 metrics: Optional[MetricsRegistry] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if admit_window is None:
            admit_window = 4 * max_batch
        if admit_window < 1:
            raise ValueError("admit_window must be >= 1")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        self.registry = registry
        self.max_batch = max_batch
        self.backend = backend
        self.admit_window = admit_window
        self.ecc_batching = ecc_batching
        self.device = device
        self.name = name if name is not None else (
            "default" if device is None
            else f"dev{getattr(device, 'id', device)}")
        self.max_pending = max_pending
        self.feedback = feedback
        self.feedback_gamma = feedback_gamma
        # every deadline/latency read goes through the injectable clock
        # (monotonic seconds), so expiry/histogram tests run on fake time
        self._clock = clock
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._pending: List[_Ticket] = []
        self._seq = 0
        self._worker: Optional[threading.Thread] = None
        self._stop = False
        self._inflight_n = 0
        # serving counters (the benchmark's occupancy/throughput inputs)
        # live in the shared MetricsRegistry — one series per scheduler
        # name; the legacy attributes below read through to them
        self.metrics = metrics if metrics is not None else registry.metrics
        lbl = {"scheduler": self.name}
        self._c_batches = self.metrics.counter(
            "sssp_scheduler_batches_total", "fused batches executed", lbl)
        self._c_done = self.metrics.counter(
            "sssp_scheduler_queries_done_total", "queries resolved", lbl)
        self._c_expired = self.metrics.counter(
            "sssp_scheduler_expired_total",
            "queries expired before admission", lbl)
        self._c_rejected = self.metrics.counter(
            "sssp_scheduler_rejected_total",
            "queries rejected at submit (queue full)", lbl)
        self._g_pending = self.metrics.gauge(
            "sssp_scheduler_pending", "tickets queued", lbl)
        self._g_inflight = self.metrics.gauge(
            "sssp_scheduler_inflight", "tickets dispatched, unfinalized",
            lbl)
        self._h_latency = self.metrics.histogram(
            "sssp_query_latency_seconds",
            "submit-to-result latency per query", lbl)

    # legacy counter attributes read through to the metrics registry
    @property
    def n_batches(self) -> int:
        return self._c_batches.value

    @property
    def n_done(self) -> int:
        return self._c_done.value

    @property
    def n_expired(self) -> int:
        return self._c_expired.value

    @property
    def n_rejected(self) -> int:
        return self._c_rejected.value

    # ------------------------------------------------------------------
    # producer side
    # ------------------------------------------------------------------

    def submit(self, query: Query, *, priority: int = 0,
               deadline_s: Optional[float] = None,
               _now: Optional[float] = None) -> Future:
        """Enqueue a query; higher ``priority`` is served first (FIFO
        within a priority level), ``deadline_s`` seconds from now bounds
        its queueing time.  Raises :class:`QueueFull` (and counts the
        rejection) when a bounded queue is at ``max_pending``.
        ``_now`` overrides the scheduler clock for this one call (tests);
        construct with ``clock=`` to fake time everywhere."""
        now = self._clock() if _now is None else _now
        fut: Future = Future()
        with self._work:
            if (self.max_pending is not None
                    and len(self._pending) >= self.max_pending):
                self._c_rejected.inc()
                raise QueueFull(
                    f"admission queue full ({self.max_pending} pending) "
                    f"on scheduler {self.name!r}; query {query} rejected")
            self._seq += 1
            self._pending.append(_Ticket(
                seq=self._seq, query=query, plan=plan(query),
                priority=priority,
                deadline=None if deadline_s is None else now + deadline_s,
                future=fut, t_submit=now))
            self._g_pending.set(len(self._pending))
            self._work.notify()
        return fut

    def outstanding(self) -> int:
        """Queued + dispatched-but-unfinished tickets.  (The router keeps
        its own per-submit load counters so routing never takes scheduler
        locks; this is the introspection equivalent.)"""
        with self._lock:
            return len(self._pending) + self._inflight_n

    # ------------------------------------------------------------------
    # batch formation + execution
    # ------------------------------------------------------------------

    def _expire_locked(self, now: float) -> None:
        live = []
        for t in self._pending:
            if t.deadline is not None and now > t.deadline:
                self._c_expired.inc()
                try:
                    t.future.set_exception(DeadlineExceeded(
                        f"query {t.query} missed its deadline by "
                        f"{now - t.deadline:.3f}s in the queue"))
                except Exception:   # racing producer-side cancel() is fine
                    pass
            else:
                live.append(t)
        self._pending = live

    def _select_locked(self) -> List[_Ticket]:
        """Pick one batch (head-of-line + cost-nearest companions)."""
        self._pending.sort(key=_Ticket.sort_key)
        window = self._pending[:self.admit_window]
        head = window[0]
        group = [t for t in window if t.plan.key == head.plan.key]
        if len(group) > self.max_batch:
            companions = group[1:]
            # peek never builds: a cold engine here would run the build
            # under the scheduler lock, stalling every producer.  On a
            # cold entry this batch gets FIFO companions; _dispatch builds
            # the engine outside the lock, so later batches cost-sort.
            eng = self.registry.peek(head.plan.gid, self.backend,
                                     device=self.device)
            if eng is not None and self.ecc_batching and self.max_batch > 1:
                try:
                    # peek only: the landmark BFS behind batch_hint must
                    # not run under this lock (_dispatch pre-pays it off
                    # the lock; until then companions stay FIFO)
                    hint = eng.peek_batch_hint()
                    if hint is not None:
                        ref = hint[head.query.source]
                        companions.sort(
                            key=lambda t: (abs(hint[t.query.source] - ref),
                                           t.seq))
                except Exception:
                    # fall back to FIFO companions; _dispatch will surface
                    # any per-ticket problem on its future
                    pass
            # the head is always admitted (no grouping starvation); the
            # hint only chooses its companion slots
            group = [head] + companions[:self.max_batch - 1]
        taken = set(id(t) for t in group)
        self._pending = [t for t in self._pending if id(t) not in taken]
        return group

    def step(self, _now: Optional[float] = None) -> bool:
        """Admit, execute and finalize one batch synchronously; returns
        whether work was done."""
        did, inflight = self._dispatch_one(_now)
        if inflight is not None:
            self._finalize(inflight)
        return did

    def _dispatch_one(self, _now: Optional[float] = None
                      ) -> Tuple[bool, Optional[_Inflight]]:
        """Admit one batch and dispatch it to the device (non-blocking)."""
        with self._lock:
            self._expire_locked(self._clock() if _now is None else _now)
            if not self._pending:
                self._g_pending.set(len(self._pending))
                return False, None
            batch = self._select_locked()
            self._g_pending.set(len(self._pending))
        batch = [t for t in batch if t.future.set_running_or_notify_cancel()]
        if not batch:
            return True, None   # all cancelled — the queue made progress
        return True, self._dispatch(batch)

    def _dispatch(self, batch: List[_Ticket]) -> Optional[_Inflight]:
        head = batch[0]
        try:
            # registry is internally locked with per-key build futures; a
            # cold build here happens outside the scheduler lock, so
            # producers (and other gids' batches) keep moving
            eng = self.registry.engine(head.plan.gid, self.backend,
                                       device=self.device)
            if self.ecc_batching and self.max_batch > 1:
                try:
                    eng.batch_hint   # pre-pay the landmark BFS off-lock
                except Exception:
                    pass             # grouping falls back to FIFO
            # out-of-range vertex ids must fail loudly here: under jit an
            # o-o-b scatter is silently dropped and a gather clamps, which
            # would return a plausible-looking wrong answer
            batch = [t for t in batch if _check_vertices(t, eng.n)]
            if not batch:
                return None
            head = batch[0]
            pad = self.max_batch - len(batch)
            # repeat slot 0 in free slots: static shape, results discarded
            plans = [t.plan for t in batch] + [head.plan] * pad
            sources = np.array([t.query.source for t in batch] +
                               [head.query.source] * pad, np.int32)
            dist, parent, metrics = eng.run_batch(   # async device dispatch
                sources, goal=head.plan.goal,
                goal_params=[p.goal_param for p in plans])
        except Exception as exc:     # engine failure fails the whole batch
            for t in batch:
                t.future.set_exception(exc)
            return None              # futures carry the error; keep serving
        with self._lock:
            self._inflight_n += len(batch)
            self._g_inflight.set(self._inflight_n)
        return _Inflight(batch=batch, eng=eng,
                         sources=sources[:len(batch)],
                         dist=dist, parent=parent, metrics=metrics)

    def _finalize(self, inflight: _Inflight) -> None:
        """Force one dispatched batch to the host and resolve its futures
        (the host half of the double buffer)."""
        batch, eng = inflight.batch, inflight.eng
        try:
            dist = np.asarray(inflight.dist)       # blocks on the device
            parent = np.asarray(inflight.parent)
            metrics = jax.tree.map(np.asarray, inflight.metrics)
        except Exception as exc:
            for t in batch:
                t.future.set_exception(exc)
            with self._lock:
                self._inflight_n -= len(batch)
                self._g_inflight.set(self._inflight_n)
            return
        if self.feedback:
            try:
                # measured rounds -> engine batch hints (EMA); padding
                # slots are excluded (sources holds real tickets only)
                eng.record_rounds(inflight.sources,
                                  metrics.n_rounds[:len(batch)],
                                  gamma=self.feedback_gamma)
            except Exception:
                pass                 # a hint failure must not fail results
        now = self._clock()
        for slot, t in enumerate(batch):
            res = finalize(t.query, eng.deg, dist[slot], parent[slot],
                           _slot_tree(metrics, slot))
            res.latency_s = now - t.t_submit
            res.served_by = self.name
            self._h_latency.observe(res.latency_s)
            t.future.set_result(res)
        with self._lock:
            self._c_batches.inc()
            self._c_done.inc(len(batch))
            self._inflight_n -= len(batch)
            self._g_inflight.set(self._inflight_n)

    def drain(self, max_steps: int = 10_000) -> int:
        """Synchronously run batches until the queue empties."""
        steps = 0
        while steps < max_steps and self.step():
            steps += 1
        return steps

    # ------------------------------------------------------------------
    # background worker (double-buffered)
    # ------------------------------------------------------------------

    def start(self) -> None:
        """Serve the queue from a daemon thread until :meth:`stop`.

        The worker keeps one batch in flight while finalizing the
        previous one: dispatch *k+1*, then force + finalize *k* — so
        host-side result shaping overlaps device compute.
        """
        if self._worker is not None:
            return
        self._stop = False

        def loop():
            inflight: Optional[_Inflight] = None
            while True:
                with self._work:
                    while (not self._pending and not self._stop
                           and inflight is None):
                        self._work.wait(timeout=0.1)
                    stop = self._stop
                nxt = None
                if not stop:
                    _, nxt = self._dispatch_one()
                if inflight is not None:
                    self._finalize(inflight)
                inflight = nxt
                if stop and inflight is None:
                    return

        self._worker = threading.Thread(
            target=loop, name=f"query-scheduler-{self.name}", daemon=True)
        self._worker.start()

    def stop(self, cancel_pending: bool = False) -> None:
        """Stop the worker thread (finalizing any in-flight batch).
        Still-queued tickets stay pending (a later
        :meth:`drain`/:meth:`start` serves them) unless
        ``cancel_pending`` — then their futures are cancelled so no
        caller blocks forever on an abandoned query."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if cancel_pending:
            with self._lock:
                dropped, self._pending = self._pending, []
            for t in dropped:
                t.future.cancel()

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """The legacy per-scheduler dict; every value is read from the
        shared :class:`~repro.obs.metrics.MetricsRegistry` series, so
        this and ``metrics.snapshot()`` can never disagree."""
        with self._lock:
            n_batches, n_done = self.n_batches, self.n_done
            occ = (n_done / (n_batches * self.max_batch)
                   if n_batches else 0.0)
            return {"name": self.name, "n_batches": n_batches,
                    "n_done": n_done, "n_expired": self.n_expired,
                    "rejected": self.n_rejected, "occupancy": occ,
                    "pending": len(self._pending),
                    "inflight": self._inflight_n,
                    "registry": self.registry.stats.as_dict()}


def _slot_tree(metrics, slot: int):
    """Index one slot out of a stacked metrics pytree."""
    return jax.tree.map(lambda x: x[slot], metrics)


def _check_vertices(t: _Ticket, n: int) -> bool:
    """Fail a ticket whose vertex ids don't exist in its graph."""
    q = t.query
    for label, v in (("source", q.source), ("target", q.target)):
        if v is not None and not 0 <= v < n:
            t.future.set_exception(ValueError(
                f"{label} {v} out of range for graph {q.gid!r} (n={n})"))
            return False
    return True
