"""Async admission layer: thread-safe query queue -> fused engine batches.

Producers call :meth:`QueryScheduler.submit` from any thread and get a
``concurrent.futures.Future`` resolving to a
:class:`~repro.serve.queries.QueryResult`.  A batch step (driven either
synchronously via :meth:`step`/:meth:`drain` or by the background worker
started with :meth:`start`) then

1. **expires** tickets whose deadline passed (``DeadlineExceeded`` on the
   future) — deadline-aware admission;
2. orders the queue by ``(priority desc, deadline, FIFO seq)`` and picks
   the head-of-line ticket — priority-aware admission;
3. restricts an ``admit_window`` of queue-front tickets to the head's
   batch-compatibility key ``(gid, goal kind)`` (one compiled engine per
   batch), then — the ROADMAP divergent-sources item — fills the
   remaining slots with the window tickets whose **estimated
   eccentricity** is nearest the head's, so a vmapped batch is not
   dominated by one long-running outlier's stepping rounds;
4. pads free slots by repeating slot 0 (static batch shape, no
   recompiles; padded results are discarded, never surfaced) and runs one
   fused ``sssp_batch`` goal query.

The head of line is always admitted, so priority/FIFO progress is
starvation-free; eccentricity grouping only chooses its *companions*.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import List, Optional

import numpy as np
import jax

from .queries import ExecutionPlan, Query, finalize, plan
from .registry import GraphRegistry

__all__ = ["DeadlineExceeded", "QueryScheduler"]


class DeadlineExceeded(Exception):
    """Raised on a query future whose deadline passed before admission."""


@dataclasses.dataclass
class _Ticket:
    seq: int
    query: Query
    plan: ExecutionPlan
    priority: int
    deadline: Optional[float]         # absolute monotonic time or None
    future: Future
    t_submit: float

    def sort_key(self):
        return (-self.priority,
                self.deadline if self.deadline is not None else float("inf"),
                self.seq)


class QueryScheduler:
    """Thread-safe admission queue over a :class:`GraphRegistry`."""

    def __init__(self, registry: GraphRegistry, *, max_batch: int = 8,
                 backend: Optional[str] = None,
                 admit_window: Optional[int] = None,
                 ecc_batching: bool = True):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if admit_window is None:
            admit_window = 4 * max_batch
        if admit_window < 1:
            raise ValueError("admit_window must be >= 1")
        self.registry = registry
        self.max_batch = max_batch
        self.backend = backend
        self.admit_window = admit_window
        self.ecc_batching = ecc_batching
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._pending: List[_Ticket] = []
        self._seq = 0
        self._worker: Optional[threading.Thread] = None
        self._stop = False
        # serving counters (the benchmark's occupancy/throughput inputs)
        self.n_batches = 0
        self.n_done = 0
        self.n_expired = 0

    # ------------------------------------------------------------------
    # producer side
    # ------------------------------------------------------------------

    def submit(self, query: Query, *, priority: int = 0,
               deadline_s: Optional[float] = None) -> Future:
        """Enqueue a query; higher ``priority`` is served first (FIFO
        within a priority level), ``deadline_s`` seconds from now bounds
        its queueing time."""
        now = time.monotonic()
        fut: Future = Future()
        with self._work:
            self._seq += 1
            self._pending.append(_Ticket(
                seq=self._seq, query=query, plan=plan(query),
                priority=priority,
                deadline=None if deadline_s is None else now + deadline_s,
                future=fut, t_submit=now))
            self._work.notify()
        return fut

    # ------------------------------------------------------------------
    # batch formation + execution
    # ------------------------------------------------------------------

    def _expire_locked(self, now: float) -> None:
        live = []
        for t in self._pending:
            if t.deadline is not None and now > t.deadline:
                self.n_expired += 1
                try:
                    t.future.set_exception(DeadlineExceeded(
                        f"query {t.query} missed its deadline by "
                        f"{now - t.deadline:.3f}s in the queue"))
                except Exception:   # racing producer-side cancel() is fine
                    pass
            else:
                live.append(t)
        self._pending = live

    def _select_locked(self) -> List[_Ticket]:
        """Pick one batch (head-of-line + ecc-nearest companions)."""
        self._pending.sort(key=_Ticket.sort_key)
        window = self._pending[:self.admit_window]
        head = window[0]
        group = [t for t in window if t.plan.key == head.plan.key]
        if len(group) > self.max_batch:
            companions = group[1:]
            # peek never builds: a cold engine here would run the build
            # under the scheduler lock, stalling every producer.  On a
            # cold entry this batch gets FIFO companions; _execute builds
            # the engine outside the lock, so later batches ecc-sort.
            eng = self.registry.peek(head.plan.gid, self.backend)
            if eng is not None and self.ecc_batching and self.max_batch > 1:
                try:
                    ecc = eng.ecc_hint
                    ref = ecc[head.query.source]
                    companions.sort(
                        key=lambda t: (abs(ecc[t.query.source] - ref),
                                       t.seq))
                except Exception:
                    # fall back to FIFO companions; _execute will surface
                    # any per-ticket problem on its future
                    pass
            # the head is always admitted (no ecc starvation); grouping
            # only chooses its companion slots
            group = [head] + companions[:self.max_batch - 1]
        taken = set(id(t) for t in group)
        self._pending = [t for t in self._pending if id(t) not in taken]
        return group

    def step(self, _now: Optional[float] = None) -> bool:
        """Admit and execute one batch; returns whether work was done."""
        with self._lock:
            self._expire_locked(time.monotonic() if _now is None else _now)
            if not self._pending:
                return False
            batch = self._select_locked()
        batch = [t for t in batch if t.future.set_running_or_notify_cancel()]
        if not batch:
            return True     # all cancelled — the queue still made progress
        self._execute(batch)
        return True

    def _execute(self, batch: List[_Ticket]) -> None:
        head = batch[0]
        try:
            # registry is internally locked; a cold build here happens
            # outside the scheduler lock, so producers keep submitting
            eng = self.registry.engine(head.plan.gid, self.backend)
            # out-of-range vertex ids must fail loudly here: under jit an
            # o-o-b scatter is silently dropped and a gather clamps, which
            # would return a plausible-looking wrong answer
            batch = [t for t in batch if _check_vertices(t, eng.g.n)]
            if not batch:
                return
            head = batch[0]
            pad = self.max_batch - len(batch)
            # repeat slot 0 in free slots: static shape, results discarded
            plans = [t.plan for t in batch] + [head.plan] * pad
            sources = np.array([t.query.source for t in batch] +
                               [head.query.source] * pad, np.int32)
            dist, parent, metrics = eng.run_batch(     # outside the lock
                sources, goal=head.plan.goal,
                goal_params=[p.goal_param for p in plans])
        except Exception as exc:     # engine failure fails the whole batch
            for t in batch:
                t.future.set_exception(exc)
            return                   # futures carry the error; keep serving
        now = time.monotonic()
        for slot, t in enumerate(batch):
            res = finalize(t.query, eng.deg, dist[slot], parent[slot],
                           _slot_tree(metrics, slot))
            res.latency_s = now - t.t_submit
            t.future.set_result(res)
        with self._lock:
            self.n_batches += 1
            self.n_done += len(batch)

    def drain(self, max_steps: int = 10_000) -> int:
        """Synchronously run batches until the queue empties."""
        steps = 0
        while steps < max_steps and self.step():
            steps += 1
        return steps

    # ------------------------------------------------------------------
    # background worker
    # ------------------------------------------------------------------

    def start(self) -> None:
        """Serve the queue from a daemon thread until :meth:`stop`."""
        if self._worker is not None:
            return
        self._stop = False

        def loop():
            while True:
                with self._work:
                    while not self._pending and not self._stop:
                        self._work.wait(timeout=0.1)
                    if self._stop:
                        return
                self.step()

        self._worker = threading.Thread(target=loop, name="query-scheduler",
                                        daemon=True)
        self._worker.start()

    def stop(self, cancel_pending: bool = False) -> None:
        """Stop the worker thread.  Still-queued tickets stay pending (a
        later :meth:`drain`/:meth:`start` serves them) unless
        ``cancel_pending`` — then their futures are cancelled so no
        caller blocks forever on an abandoned query."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if cancel_pending:
            with self._lock:
                dropped, self._pending = self._pending, []
            for t in dropped:
                t.future.cancel()

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            occ = (self.n_done / (self.n_batches * self.max_batch)
                   if self.n_batches else 0.0)
            return {"n_batches": self.n_batches, "n_done": self.n_done,
                    "n_expired": self.n_expired, "occupancy": occ,
                    "pending": len(self._pending),
                    "registry": self.registry.stats.as_dict()}


def _slot_tree(metrics, slot: int):
    """Index one slot out of a stacked metrics pytree."""
    return jax.tree.map(lambda x: x[slot], metrics)


def _check_vertices(t: _Ticket, n: int) -> bool:
    """Fail a ticket whose vertex ids don't exist in its graph."""
    q = t.query
    for label, v in (("source", q.source), ("target", q.target)):
        if v is not None and not 0 <= v < n:
            t.future.set_exception(ValueError(
                f"{label} {v} out of range for graph {q.gid!r} (n={n})"))
            return False
    return True
